//! Custom micro-bench harness (S15; criterion is not in the offline
//! registry). Warmup + repeated timed runs, reporting median and MAD so
//! bench drivers can print stable paper-style rows. The `json` submodule
//! adds the machine-readable `BENCH_*.json` emitter the CI perf
//! trajectory is tracked with.

pub mod json;

use crate::util::Timer;

/// Result of a timed measurement.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub median_secs: f64,
    pub mad_secs: f64,
    pub min_secs: f64,
    pub runs: usize,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median_secs * 1e3
    }

    pub fn fmt_ms(&self) -> String {
        format!("{:.3}±{:.3}ms", self.median_secs * 1e3, self.mad_secs * 1e3)
    }
}

/// Time `f` with `warmup` unmeasured runs then `runs` measured runs.
/// A black-box sink defeats dead-code elimination on the closure result.
pub fn bench<T>(warmup: usize, runs: usize, mut f: impl FnMut() -> T) -> Measurement {
    assert!(runs >= 1);
    for _ in 0..warmup {
        sink(f());
    }
    let mut samples: Vec<f64> = (0..runs)
        .map(|_| {
            let t = Timer::start();
            sink(f());
            t.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        median_secs: median,
        mad_secs: devs[devs.len() / 2],
        min_secs: samples[0],
        runs,
    }
}

/// Adaptive run count: quick functions get more repetitions.
pub fn bench_auto<T>(mut f: impl FnMut() -> T) -> Measurement {
    let (_, probe) = Timer::time(|| sink(f()));
    let runs = if probe < 1e-4 {
        50
    } else if probe < 1e-2 {
        15
    } else if probe < 0.5 {
        5
    } else {
        3
    };
    bench(1, runs, f)
}

/// Opaque value sink (std::hint::black_box).
#[inline]
pub fn sink<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let m = bench(1, 5, || (0..1000u64).sum::<u64>());
        assert!(m.median_secs >= 0.0);
        assert_eq!(m.runs, 5);
        assert!(m.min_secs <= m.median_secs);
    }

    #[test]
    fn auto_picks_more_runs_for_fast_fns() {
        let m = bench_auto(|| 1 + 1);
        assert!(m.runs >= 15);
    }

    #[test]
    fn fmt_renders() {
        let m = Measurement {
            median_secs: 0.001,
            mad_secs: 0.0001,
            min_secs: 0.0009,
            runs: 5,
        };
        assert!(m.fmt_ms().contains("1.000"));
    }
}
