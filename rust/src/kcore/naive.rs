//! Naive k-core peel — the paper's Algorithm 1, verbatim: repeatedly
//! delete vertices of degree < k until none remain. O(n·m) worst case;
//! retained as the oracle for the Batagelj–Zaveršnik implementation.

use crate::graph::Graph;

/// Vertices surviving in the k-core, by iterative deletion.
pub fn kcore_members_naive(g: &Graph, k: usize) -> Vec<bool> {
    let n = g.n();
    let mut alive = vec![true; n];
    let mut deg: Vec<usize> = g.degrees();
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n {
            if alive[v] && deg[v] < k {
                alive[v] = false;
                changed = true;
                for &w in g.neighbors(v as u32) {
                    if alive[w as usize] {
                        deg[w as usize] -= 1;
                    }
                }
            }
        }
    }
    alive
}

/// Coreness of every vertex by running the peel for increasing k.
/// O(n·m·degeneracy) — test oracle only.
pub fn coreness_naive(g: &Graph) -> Vec<usize> {
    let n = g.n();
    let mut core = vec![0usize; n];
    let mut k = 1;
    loop {
        let alive = kcore_members_naive(g, k);
        if !alive.iter().any(|&a| a) {
            break;
        }
        for v in 0..n {
            if alive[v] {
                core[v] = k;
            }
        }
        k += 1;
    }
    core
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn star_peels_to_nothing_at_2() {
        let g = gen::star(6);
        let alive = kcore_members_naive(&g, 2);
        assert!(alive.iter().all(|&a| !a));
    }

    #[test]
    fn triangle_with_tail() {
        let g = crate::graph::Graph::from_edges(4, &[(0, 1), (1, 2), (0, 2), (2, 3)]);
        let alive = kcore_members_naive(&g, 2);
        assert_eq!(alive, vec![true, true, true, false]);
        assert_eq!(coreness_naive(&g), vec![2, 2, 2, 1]);
    }

    #[test]
    fn zero_core_is_everything() {
        let g = gen::path(5);
        assert!(kcore_members_naive(&g, 0).iter().all(|&a| a));
    }
}
