//! k-core decomposition (S3) — the substrate of the CoralTDA theorem.
//!
//! Two implementations: a naive iterative peel (`naive`, the paper's
//! Algorithm 1 — kept as the test oracle) and the Batagelj–Zaveršnik
//! O(n + m) bucket algorithm (`bz`, the production path). Both agree on
//! every graph (property-tested).

pub mod bz;
pub mod naive;

pub use bz::{coreness, peel_residue};

use crate::graph::Graph;

/// The k-core `G^k`: the maximal subgraph with all degrees ≥ k.
///
/// Returns the core subgraph and the `new id -> old id` mapping needed to
/// restrict a filtering function to the core (paper Remark 1: f keeps its
/// *original* values on surviving vertices).
pub fn kcore_subgraph(g: &Graph, k: usize) -> (Graph, Vec<u32>) {
    let core = coreness(g);
    let keep: Vec<bool> = core.iter().map(|&c| c >= k).collect();
    g.induced(&keep)
}

/// Degeneracy: max k with non-empty k-core.
pub fn degeneracy(g: &Graph) -> usize {
    coreness(g).into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn kcore_of_cycle() {
        let g = gen::cycle(8);
        let (c2, ids) = kcore_subgraph(&g, 2);
        assert_eq!(c2.n(), 8);
        assert_eq!(ids.len(), 8);
        let (c3, _) = kcore_subgraph(&g, 3);
        assert_eq!(c3.n(), 0, "cycles have empty 3-core (Remark 11)");
    }

    #[test]
    fn kcore_of_complete() {
        let g = gen::complete(6);
        assert_eq!(degeneracy(&g), 5);
        let (c5, _) = kcore_subgraph(&g, 5);
        assert_eq!(c5.n(), 6);
        let (c6, _) = kcore_subgraph(&g, 6);
        assert_eq!(c6.n(), 0);
    }

    #[test]
    fn paper_figure1_shape() {
        // A graph with an isolated vertex: it sits in the 0-core only.
        let mut edges = vec![(1u32, 2u32), (2, 3), (1, 3)];
        edges.push((3, 4));
        let g = Graph::from_edges(5, &edges); // vertex 0 isolated
        let core = coreness(&g);
        assert_eq!(core[0], 0);
        assert_eq!(core[4], 1);
        assert_eq!(core[1], 2);
    }

    #[test]
    fn cores_are_nested() {
        let g = gen::barabasi_albert(150, 3, 11);
        let mut prev = g.n() + 1;
        for k in 0..=degeneracy(&g) + 1 {
            let (ck, _) = kcore_subgraph(&g, k);
            assert!(ck.n() <= prev, "G^{k} must be ⊆ G^{}", k.saturating_sub(1));
            prev = ck.n();
        }
    }

    #[test]
    fn core_subgraph_min_degree() {
        let g = gen::erdos_renyi(120, 0.06, 13);
        for k in 1..=4 {
            let (ck, _) = kcore_subgraph(&g, k);
            for v in 0..ck.n() as u32 {
                assert!(ck.degree(v) >= k, "vertex {v} has degree < {k} in {k}-core");
            }
        }
    }
}
