//! Batagelj–Zaveršnik O(n + m) core decomposition ([5] in the paper):
//! bucket vertices by current degree, peel in ascending degree order,
//! decrementing neighbours in place via the position/bucket bookkeeping.

use crate::graph::Graph;

/// Coreness (core number) of every vertex in O(n + m).
pub fn coreness(g: &Graph) -> Vec<usize> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let mut deg: Vec<usize> = g.degrees();
    let max_deg = deg.iter().copied().max().unwrap_or(0);

    // bin[d] = start index of the degree-d block in `vert`.
    let mut bin = vec![0usize; max_deg + 2];
    for &d in &deg {
        bin[d + 1] += 1;
    }
    for d in 1..bin.len() {
        bin[d] += bin[d - 1];
    }
    // vert: vertices sorted by degree; pos[v] = index of v in vert.
    let mut vert = vec![0u32; n];
    let mut pos = vec![0usize; n];
    {
        let mut next = bin.clone();
        for v in 0..n {
            let d = deg[v];
            vert[next[d]] = v as u32;
            pos[v] = next[d];
            next[d] += 1;
        }
    }

    let mut core = vec![0usize; n];
    for i in 0..n {
        let v = vert[i] as usize;
        core[v] = deg[v];
        for idx in 0..g.degree(v as u32) {
            let u = g.neighbors(v as u32)[idx] as usize;
            if deg[u] > deg[v] {
                // Swap u with the first vertex of its degree block, then
                // shrink the block boundary — an O(1) degree decrement.
                let du = deg[u];
                let pu = pos[u];
                let pw = bin[du];
                let w = vert[pw] as usize;
                if u != w {
                    vert[pu] = w as u32;
                    vert[pw] = u as u32;
                    pos[u] = pw;
                    pos[w] = pu;
                }
                bin[du] += 1;
                deg[u] -= 1;
            }
        }
    }
    core
}

/// Peel a tombstoned residue of `g` down to its `k`-core **in place**:
/// kill every alive vertex whose residual degree is below `k`, cascading
/// until all survivors have degree ≥ k — the Batagelj–Zaveršnik peel
/// specialised to a fixed threshold, so the degree-bucket array collapses
/// to a single below-`k` worklist and the pass is O(n + removed edges).
///
/// `alive[v]` and `deg[v]` (the residual degree, i.e. alive neighbours
/// only) are updated in place; `deg` of killed vertices is left stale.
/// `stack` is caller-owned scratch. Returns the number of vertices
/// removed.
pub fn peel_residue(
    g: &Graph,
    k: u32,
    alive: &mut [bool],
    deg: &mut [u32],
    stack: &mut Vec<u32>,
) -> usize {
    debug_assert_eq!(alive.len(), g.n());
    debug_assert_eq!(deg.len(), g.n());
    debug_assert!(stack.is_empty());
    let mut removed = 0usize;
    for v in 0..g.n() {
        if alive[v] && deg[v] < k {
            alive[v] = false;
            stack.push(v as u32);
        }
    }
    while let Some(v) = stack.pop() {
        removed += 1;
        for &w in g.neighbors(v) {
            if alive[w as usize] {
                deg[w as usize] -= 1;
                if deg[w as usize] < k {
                    alive[w as usize] = false;
                    stack.push(w);
                }
            }
        }
    }
    removed
}

#[cfg(test)]
mod tests {
    use super::super::naive::coreness_naive;
    use super::*;
    use crate::graph::{gen, Graph};
    use crate::util::Rng;

    #[test]
    fn matches_naive_on_small_families() {
        for g in [
            gen::cycle(9),
            gen::complete(7),
            gen::star(10),
            gen::path(6),
            gen::grid(4, 5),
            gen::octahedron(),
            Graph::empty(5),
        ] {
            assert_eq!(coreness(&g), coreness_naive(&g));
        }
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        let mut rng = Rng::new(99);
        for trial in 0..40 {
            let n = rng.range(2, 60);
            let p = rng.f64() * 0.25;
            let g = gen::erdos_renyi(n, p, rng.next_u64());
            assert_eq!(
                coreness(&g),
                coreness_naive(&g),
                "trial {trial}: n={n} p={p:.3}"
            );
        }
    }

    #[test]
    fn matches_naive_on_ba() {
        for seed in 0..5 {
            let g = gen::barabasi_albert(120, 3, seed);
            assert_eq!(coreness(&g), coreness_naive(&g));
        }
    }

    #[test]
    fn peel_residue_matches_kcore_subgraph() {
        let mut rng = Rng::new(17);
        for trial in 0..25 {
            let n = rng.range(2, 80);
            let g = gen::erdos_renyi(n, 0.12, rng.next_u64());
            for k in 1..=4u32 {
                let mut alive = vec![true; g.n()];
                let mut deg: Vec<u32> = (0..g.n() as u32).map(|v| g.degree(v) as u32).collect();
                let mut stack = Vec::new();
                let cnt = peel_residue(&g, k, &mut alive, &mut deg, &mut stack);
                let (core, ids) = crate::kcore::kcore_subgraph(&g, k as usize);
                let survivors: Vec<u32> = (0..g.n() as u32)
                    .filter(|&v| alive[v as usize])
                    .collect();
                assert_eq!(survivors, ids, "trial {trial} k={k}");
                assert_eq!(cnt, g.n() - core.n());
                // residual degrees of survivors match the core subgraph
                for (new, &old) in ids.iter().enumerate() {
                    assert_eq!(deg[old as usize] as usize, core.degree(new as u32));
                }
            }
        }
    }

    #[test]
    fn peel_residue_on_a_tombstoned_residue() {
        // kill vertex 0 of a star by hand: the 1-core peel must then drop
        // every leaf (their residual degree is 0), using residual degrees.
        let g = gen::star(6);
        let mut alive = vec![true; g.n()];
        let mut deg: Vec<u32> = (0..g.n() as u32).map(|v| g.degree(v) as u32).collect();
        alive[0] = false;
        for leaf in 1..6 {
            deg[leaf] -= 1;
        }
        let mut stack = Vec::new();
        let cnt = peel_residue(&g, 1, &mut alive, &mut deg, &mut stack);
        assert_eq!(cnt, 5);
        assert!((1..6).all(|v| !alive[v]), "all leaves must peel");
    }

    #[test]
    fn coreness_bounded_by_degree() {
        let g = gen::powerlaw_cluster(200, 3, 0.5, 8);
        let core = coreness(&g);
        for v in 0..g.n() {
            assert!(core[v] <= g.degree(v as u32));
        }
    }

    #[test]
    fn empty_and_singleton() {
        assert!(coreness(&Graph::empty(0)).is_empty());
        assert_eq!(coreness(&Graph::empty(3)), vec![0, 0, 0]);
    }
}
