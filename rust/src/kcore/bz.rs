//! Batagelj–Zaveršnik O(n + m) core decomposition ([5] in the paper):
//! bucket vertices by current degree, peel in ascending degree order,
//! decrementing neighbours in place via the position/bucket bookkeeping.

use crate::graph::Graph;

/// Coreness (core number) of every vertex in O(n + m).
pub fn coreness(g: &Graph) -> Vec<usize> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    let mut deg: Vec<usize> = g.degrees();
    let max_deg = deg.iter().copied().max().unwrap_or(0);

    // bin[d] = start index of the degree-d block in `vert`.
    let mut bin = vec![0usize; max_deg + 2];
    for &d in &deg {
        bin[d + 1] += 1;
    }
    for d in 1..bin.len() {
        bin[d] += bin[d - 1];
    }
    // vert: vertices sorted by degree; pos[v] = index of v in vert.
    let mut vert = vec![0u32; n];
    let mut pos = vec![0usize; n];
    {
        let mut next = bin.clone();
        for v in 0..n {
            let d = deg[v];
            vert[next[d]] = v as u32;
            pos[v] = next[d];
            next[d] += 1;
        }
    }

    let mut core = vec![0usize; n];
    for i in 0..n {
        let v = vert[i] as usize;
        core[v] = deg[v];
        for idx in 0..g.degree(v as u32) {
            let u = g.neighbors(v as u32)[idx] as usize;
            if deg[u] > deg[v] {
                // Swap u with the first vertex of its degree block, then
                // shrink the block boundary — an O(1) degree decrement.
                let du = deg[u];
                let pu = pos[u];
                let pw = bin[du];
                let w = vert[pw] as usize;
                if u != w {
                    vert[pu] = w as u32;
                    vert[pw] = u as u32;
                    pos[u] = pw;
                    pos[w] = pu;
                }
                bin[du] += 1;
                deg[u] -= 1;
            }
        }
    }
    core
}

#[cfg(test)]
mod tests {
    use super::super::naive::coreness_naive;
    use super::*;
    use crate::graph::{gen, Graph};
    use crate::util::Rng;

    #[test]
    fn matches_naive_on_small_families() {
        for g in [
            gen::cycle(9),
            gen::complete(7),
            gen::star(10),
            gen::path(6),
            gen::grid(4, 5),
            gen::octahedron(),
            Graph::empty(5),
        ] {
            assert_eq!(coreness(&g), coreness_naive(&g));
        }
    }

    #[test]
    fn matches_naive_on_random_graphs() {
        let mut rng = Rng::new(99);
        for trial in 0..40 {
            let n = rng.range(2, 60);
            let p = rng.f64() * 0.25;
            let g = gen::erdos_renyi(n, p, rng.next_u64());
            assert_eq!(
                coreness(&g),
                coreness_naive(&g),
                "trial {trial}: n={n} p={p:.3}"
            );
        }
    }

    #[test]
    fn matches_naive_on_ba() {
        for seed in 0..5 {
            let g = gen::barabasi_albert(120, 3, seed);
            assert_eq!(coreness(&g), coreness_naive(&g));
        }
    }

    #[test]
    fn coreness_bounded_by_degree() {
        let g = gen::powerlaw_cluster(200, 3, 0.5, 8);
        let core = coreness(&g);
        for v in 0..g.n() {
            assert!(core[v] <= g.degree(v as u32));
        }
    }

    #[test]
    fn empty_and_singleton() {
        assert!(coreness(&Graph::empty(0)).is_empty());
        assert_eq!(coreness(&Graph::empty(3)), vec![0, 0, 0]);
    }
}
