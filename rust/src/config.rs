//! Configuration system (S14): a TOML-subset parser (sections, string /
//! number / bool scalars, `#` comments) feeding typed experiment and
//! coordinator configs. serde/toml are unavailable offline; this subset
//! covers everything the launcher needs.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

/// Parsed config: `section.key -> raw string value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!(
                        "line {}: unterminated section header",
                        lineno + 1
                    )));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section", lineno + 1)));
                }
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(Error::Config(format!(
                    "line {}: expected `key = value`",
                    lineno + 1
                )));
            };
            let key = line[..eq].trim();
            let mut val = line[eq + 1..].trim().to_string();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            // strip matched quotes
            if val.len() >= 2
                && ((val.starts_with('"') && val.ends_with('"'))
                    || (val.starts_with('\'') && val.ends_with('\'')))
            {
                val = val[1..val.len() - 1].to_string();
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::Io(format!("{}: {e}", path.as_ref().display())))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("{key}: expected number, got {v:?}"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("{key}: expected integer, got {v:?}"))),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("yes") | Some("1") => Ok(true),
            Some("false") | Some("no") | Some("0") => Ok(false),
            Some(v) => Err(Error::Config(format!("{key}: expected bool, got {v:?}"))),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|k| k.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // naive: a `#` outside quotes starts a comment
    let mut in_quote: Option<char> = None;
    for (i, c) in line.char_indices() {
        match (c, in_quote) {
            ('"', None) | ('\'', None) => in_quote = Some(c),
            (q, Some(open)) if q == open => in_quote = None,
            ('#', None) => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Typed batch-coordinator config (see `coordinator`).
#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub max_k: usize,
    pub reduction: String,
    pub seed: u64,
    /// PrunIT frontier check-phase threads per job (`--prune-threads`).
    /// `0` = adaptive: each round picks its own thread count from a
    /// measured per-check cost model; `1` (the default) forces the
    /// inline sequential sweep; `T >= 2` pins `T` threads for rounds
    /// past the parallel threshold. Results are bit-identical at every
    /// setting. Inner parallelism multiplies with `workers`, so the
    /// default keeps jobs single-threaded and lets the pool own the
    /// cores.
    pub prune_threads: usize,
    /// Domination-kernel policy per job (`--domination-kernel`):
    /// `auto` (per-round density choice), `merge`, or `bitset`. Residues
    /// are bit-identical at every setting; only wall time changes.
    pub domination_kernel: String,
    /// Per-job wall-clock deadline in seconds (`--job-deadline-secs`).
    /// `0` (the default) disables deadlines. A job past its deadline
    /// unwinds at the next cancellation checkpoint with
    /// `Error::DeadlineExceeded` and enters the retry ladder.
    pub job_deadline_secs: f64,
    /// Retries after a transient failure (`--max-retries`); attempts =
    /// `max_retries + 1`. Each retry escalates the reduction (see
    /// `coordinator::worker::degraded_spec`) so the job gets cheaper
    /// before it is dropped. Permanent errors are never retried.
    pub max_retries: usize,
    /// Base backoff between attempts in milliseconds, doubled per retry
    /// with seeded equal-jitter (see
    /// `coordinator::worker::jittered_backoff_ms`).
    pub retry_backoff_ms: u64,
    /// Seed for the retry-backoff jitter, mixed with job id and attempt
    /// (`coordinator.retry_jitter_seed`). A fixed seed keeps backoff
    /// schedules reproducible across runs.
    pub retry_jitter_seed: u64,
    /// Graph order at which a job counts as outsized and routes past the
    /// scratch pool to the dedicated high-tier worker
    /// (`--large-job-order`). `0` (the default) resolves to the first
    /// order in the pool's top tier
    /// (`coordinator::scratch::top_tier_min_order`).
    pub large_job_order: usize,
    /// Journal size in bytes past which `Coordinator::run_resumable`
    /// compacts the file (drops superseded per-job history) before
    /// appending (`coordinator.journal_compact_bytes`; `0` disables).
    pub journal_compact_bytes: u64,
    /// Persistence reduction algorithm per job (`--ph-algorithm`):
    /// `standard`, `twist`, or `chunked`. Diagrams are bit-identical at
    /// every setting; only wall time changes.
    pub ph_algorithm: String,
    /// Threads for the chunked persistence reduction (`--ph-threads`).
    /// `0` resolves to available parallelism; `1` (the default) keeps
    /// jobs single-threaded so the worker pool owns the cores. Sharded
    /// execution splits this budget across shard workers instead of
    /// oversubscribing.
    pub ph_threads: usize,
}

impl CoordinatorConfig {
    pub fn from_config(cfg: &Config) -> Result<CoordinatorConfig> {
        let default_workers = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(2);
        Ok(CoordinatorConfig {
            workers: cfg.get_usize("coordinator.workers", default_workers)?,
            queue_depth: cfg.get_usize("coordinator.queue_depth", 64)?,
            max_k: cfg.get_usize("coordinator.max_k", 1)?,
            reduction: cfg.get_str("coordinator.reduction", "prunit+coral"),
            seed: cfg.get_u64("coordinator.seed", 42)?,
            prune_threads: cfg.get_usize("coordinator.prune_threads", 1)?,
            domination_kernel: cfg.get_str("coordinator.domination_kernel", "auto"),
            job_deadline_secs: cfg.get_f64("coordinator.job_deadline_secs", 0.0)?,
            max_retries: cfg.get_usize("coordinator.max_retries", 2)?,
            retry_backoff_ms: cfg.get_u64("coordinator.retry_backoff_ms", 25)?,
            retry_jitter_seed: cfg.get_u64("coordinator.retry_jitter_seed", 42)?,
            large_job_order: cfg.get_usize("coordinator.large_job_order", 0)?,
            journal_compact_bytes: cfg.get_u64("coordinator.journal_compact_bytes", 1 << 20)?,
            ph_algorithm: cfg.get_str("coordinator.ph_algorithm", "twist"),
            ph_threads: cfg.get_usize("coordinator.ph_threads", 1)?,
        })
    }
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig::from_config(&Config::default()).unwrap()
    }
}

/// Typed config for the always-on reduction service (`repro serve`),
/// read from the `[service]` section. Admission-control limits mirror
/// `coordinator::admission::AdmissionPolicy`; the rest parameterise the
/// result cache, the watchdog, and the health endpoint.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceConfig {
    /// `host:port` for the hand-rolled HTTP health/metrics listener
    /// (`service.http_addr`); empty disables the endpoint.
    pub http_addr: String,
    /// Content-addressed result-cache byte budget
    /// (`service.cache_budget_bytes`; `0` disables caching).
    pub cache_budget_bytes: usize,
    /// Hard cap on queued-but-unfinished jobs (`service.max_pending`).
    pub max_pending: usize,
    /// Pending depth where priority-ramped shedding starts
    /// (`service.shed_pending`).
    pub shed_pending: usize,
    /// Estimated-bytes budget for admitted in-flight jobs
    /// (`service.memory_budget_bytes`).
    pub memory_budget_bytes: usize,
    /// Estimated CPU backlog (pending × observed mean job seconds) past
    /// which new jobs are degraded to FixedPoint + sharded instead of
    /// shed (`service.cpu_pressure_secs`; `0` disables degrading).
    pub cpu_pressure_secs: f64,
    /// Scratch arenas idle longer than this are evicted by the watchdog
    /// (`service.idle_evict_secs`; `0` disables idle eviction).
    pub idle_evict_secs: f64,
    /// Watchdog poll cadence in milliseconds
    /// (`service.watchdog_poll_ms`).
    pub watchdog_poll_ms: u64,
    /// No-deadline in-flight attempts older than this are cancelled by
    /// the watchdog (`service.stuck_job_secs`; `0` disables).
    pub stuck_job_secs: f64,
    /// Grace added on top of per-attempt deadlines before the watchdog
    /// force-cancels (`service.watchdog_grace_secs`) — the cooperative
    /// deadline normally unwinds the attempt itself first.
    pub watchdog_grace_secs: f64,
}

impl ServiceConfig {
    pub fn from_config(cfg: &Config) -> Result<ServiceConfig> {
        Ok(ServiceConfig {
            http_addr: cfg.get_str("service.http_addr", ""),
            cache_budget_bytes: cfg.get_usize("service.cache_budget_bytes", 64 << 20)?,
            max_pending: cfg.get_usize("service.max_pending", 256)?,
            shed_pending: cfg.get_usize("service.shed_pending", 128)?,
            memory_budget_bytes: cfg.get_usize("service.memory_budget_bytes", 2 << 30)?,
            cpu_pressure_secs: cfg.get_f64("service.cpu_pressure_secs", 30.0)?,
            idle_evict_secs: cfg.get_f64("service.idle_evict_secs", 30.0)?,
            watchdog_poll_ms: cfg.get_u64("service.watchdog_poll_ms", 50)?,
            stuck_job_secs: cfg.get_f64("service.stuck_job_secs", 300.0)?,
            watchdog_grace_secs: cfg.get_f64("service.watchdog_grace_secs", 2.0)?,
        })
    }
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig::from_config(&Config::default()).unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let cfg = Config::parse(
            "top = 1\n[coordinator]\nworkers = 4\nreduction = \"prunit\"\n# comment\nflag = true\n",
        )
        .unwrap();
        assert_eq!(cfg.get("top"), Some("1"));
        assert_eq!(cfg.get_usize("coordinator.workers", 0).unwrap(), 4);
        assert_eq!(cfg.get_str("coordinator.reduction", ""), "prunit");
        assert!(cfg.get_bool("coordinator.flag", false).unwrap());
    }

    #[test]
    fn inline_comments_stripped_outside_quotes() {
        let cfg = Config::parse("a = 5 # five\nb = \"x # y\"\n").unwrap();
        assert_eq!(cfg.get("a"), Some("5"));
        assert_eq!(cfg.get("b"), Some("x # y"));
    }

    #[test]
    fn errors_are_specific() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("no_equals_here\n").is_err());
        assert!(Config::parse("= novalue\n").is_err());
        let cfg = Config::parse("n = abc\n").unwrap();
        assert!(cfg.get_usize("n", 0).is_err());
        assert!(cfg.get_bool("n", false).is_err());
    }

    #[test]
    fn defaults_apply_when_missing() {
        let cfg = Config::parse("").unwrap();
        assert_eq!(cfg.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(cfg.get_f64("missing", 2.5).unwrap(), 2.5);
        assert_eq!(cfg.get_str("missing", "d"), "d");
    }

    #[test]
    fn coordinator_config_from_toml() {
        let cfg = Config::parse(
            "[coordinator]\nworkers = 3\nqueue_depth = 16\nmax_k = 2\nseed = 9\nprune_threads = 4\n",
        )
        .unwrap();
        let cc = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.workers, 3);
        assert_eq!(cc.queue_depth, 16);
        assert_eq!(cc.max_k, 2);
        assert_eq!(cc.seed, 9);
        assert_eq!(cc.reduction, "prunit+coral");
        assert_eq!(cc.prune_threads, 4);
        assert_eq!(cc.domination_kernel, "auto");
    }

    #[test]
    fn prune_threads_defaults_to_sequential() {
        let cc = CoordinatorConfig::default();
        assert_eq!(cc.prune_threads, 1);
    }

    #[test]
    fn large_job_order_key_is_read_with_zero_default() {
        assert_eq!(CoordinatorConfig::default().large_job_order, 0);
        let cfg = Config::parse("[coordinator]\nlarge_job_order = 5000\n").unwrap();
        let cc = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.large_job_order, 5000);
    }

    #[test]
    fn service_and_jitter_keys_are_read_with_defaults() {
        let dflt = ServiceConfig::default();
        assert_eq!(dflt.http_addr, "");
        assert_eq!(dflt.cache_budget_bytes, 64 << 20);
        assert_eq!(dflt.max_pending, 256);
        assert_eq!(dflt.shed_pending, 128);
        assert_eq!(dflt.cpu_pressure_secs, 30.0);
        assert_eq!(CoordinatorConfig::default().retry_jitter_seed, 42);
        assert_eq!(CoordinatorConfig::default().journal_compact_bytes, 1 << 20);
        let cfg = Config::parse(
            "[coordinator]\nretry_jitter_seed = 7\njournal_compact_bytes = 4096\n\
             [service]\nhttp_addr = \"127.0.0.1:9090\"\ncache_budget_bytes = 1024\n\
             max_pending = 8\nshed_pending = 4\nmemory_budget_bytes = 1000000\n\
             cpu_pressure_secs = 1.5\nidle_evict_secs = 0\nwatchdog_poll_ms = 10\n\
             stuck_job_secs = 60\nwatchdog_grace_secs = 0.5\n",
        )
        .unwrap();
        let cc = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.retry_jitter_seed, 7);
        assert_eq!(cc.journal_compact_bytes, 4096);
        let sc = ServiceConfig::from_config(&cfg).unwrap();
        assert_eq!(sc.http_addr, "127.0.0.1:9090");
        assert_eq!(sc.cache_budget_bytes, 1024);
        assert_eq!(sc.max_pending, 8);
        assert_eq!(sc.shed_pending, 4);
        assert_eq!(sc.memory_budget_bytes, 1_000_000);
        assert_eq!(sc.cpu_pressure_secs, 1.5);
        assert_eq!(sc.idle_evict_secs, 0.0);
        assert_eq!(sc.watchdog_poll_ms, 10);
        assert_eq!(sc.stuck_job_secs, 60.0);
        assert_eq!(sc.watchdog_grace_secs, 0.5);
    }

    #[test]
    fn domination_kernel_key_is_read() {
        let cfg = Config::parse("[coordinator]\ndomination_kernel = \"bitset\"\n").unwrap();
        let cc = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.domination_kernel, "bitset");
        assert_eq!(CoordinatorConfig::default().domination_kernel, "auto");
    }

    #[test]
    fn ph_keys_are_read_with_defaults() {
        let dflt = CoordinatorConfig::default();
        assert_eq!(dflt.ph_algorithm, "twist");
        assert_eq!(dflt.ph_threads, 1);
        let cfg =
            Config::parse("[coordinator]\nph_algorithm = \"chunked\"\nph_threads = 4\n").unwrap();
        let cc = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.ph_algorithm, "chunked");
        assert_eq!(cc.ph_threads, 4);
    }

    #[test]
    fn fault_tolerance_keys_are_read_with_defaults() {
        let dflt = CoordinatorConfig::default();
        assert_eq!(dflt.job_deadline_secs, 0.0, "deadlines off by default");
        assert_eq!(dflt.max_retries, 2);
        assert_eq!(dflt.retry_backoff_ms, 25);
        let cfg = Config::parse(
            "[coordinator]\njob_deadline_secs = 1.5\nmax_retries = 5\nretry_backoff_ms = 100\n",
        )
        .unwrap();
        let cc = CoordinatorConfig::from_config(&cfg).unwrap();
        assert_eq!(cc.job_deadline_secs, 1.5);
        assert_eq!(cc.max_retries, 5);
        assert_eq!(cc.retry_backoff_ms, 100);
    }
}
