//! `repro` — the leader binary: CLI over the coral-prunit library.
//! See `repro help` and README.md for the experiment index.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match coral_prunit::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
