//! The named dataset registry: every paper dataset mapped to a recipe.
//! Table 2 statistics (AvgNumNodes / AvgNumEdges) drive the parameters;
//! instance counts are capped (`instances`) so full-PH baselines finish
//! on this testbed — the caps and scale factors are recorded in
//! EXPERIMENTS.md per experiment.

use super::recipes::{Family, Recipe};
use crate::error::{Error, Result};

/// Graph-classification (kernel + ego) dataset stand-ins — paper Table 2.
pub fn kernel_datasets() -> Vec<Recipe> {
    vec![
        // DD: 1178 graphs, avg 284.3 nodes / 715.7 edges (protein structure)
        Recipe { name: "DD", n: 284, jitter: 0.4, family: Family::Rgg { r: 0.075 }, instances: 12, scale_down: 1 },
        // DHFR: 467 graphs, 42.4 / 44.5 (molecules: trees + rings)
        Recipe { name: "DHFR", n: 42, jitter: 0.25, family: Family::Molecule { extra: 3 }, instances: 20, scale_down: 1 },
        // ENZYMES: 600 graphs, 32.6 / 62.1
        Recipe { name: "ENZYMES", n: 33, jitter: 0.3, family: Family::Rgg { r: 0.21 }, instances: 20, scale_down: 1 },
        // FIRSTMM: 41 graphs, 1377 / 3074 (3d point-cloud meshes → strong cores)
        Recipe { name: "FIRSTMM", n: 1377, jitter: 0.2, family: Family::Mesh { diag_frac: 0.55 }, instances: 4, scale_down: 1 },
        // NCI1: 4110 graphs, 29.9 / 32.3 (molecules)
        Recipe { name: "NCI1", n: 30, jitter: 0.25, family: Family::Molecule { extra: 2 }, instances: 20, scale_down: 1 },
        // OHSU: 79 graphs, 82.0 / 199.7 (brain networks: dense modules →
        // high coreness but plenty of intra-module twins)
        Recipe { name: "OHSU", n: 82, jitter: 0.2, family: Family::CliqueCover { k: 7, overlap: 0.3 }, instances: 12, scale_down: 1 },
        // PROTEINS: 1113 graphs, 39.1 / 72.8
        Recipe { name: "PROTEINS", n: 39, jitter: 0.3, family: Family::Rgg { r: 0.2 }, instances: 20, scale_down: 1 },
        // REDDIT-BINARY: 2000 graphs, 429.6 / 497.8 (discussion trees + hubs)
        Recipe { name: "REDDIT-BINARY", n: 430, jitter: 0.4, family: Family::Social { m: 1, leaf_frac: 0.5 }, instances: 10, scale_down: 1 },
        // SYNNEW: 300 graphs, 100 / 196.3 (synthetic, strong cores → low PrunIT)
        Recipe { name: "SYNNEW", n: 100, jitter: 0.05, family: Family::Er { p: 0.0397 }, instances: 15, scale_down: 1 },
        // TWITTER: 973 graphs, 83.5 / 1817 (dense ego nets + ~20% rim)
        Recipe { name: "TWITTER", n: 84, jitter: 0.25, family: Family::Ego { m: 14, pt: 0.85, periphery: 0.22 }, instances: 10, scale_down: 2 },
        // FACEBOOK: 10 graphs, 403.9 / 8823.4 (dense ego nets + rim)
        Recipe { name: "FACEBOOK", n: 240, jitter: 0.2, family: Family::Ego { m: 14, pt: 0.9, periphery: 0.2 }, instances: 4, scale_down: 2 },
    ]
}

/// Node-classification dataset stand-ins (single citation graphs).
pub fn node_datasets() -> Vec<Recipe> {
    vec![
        // CORA: 2708 nodes / 5429 edges
        Recipe { name: "CORA", n: 2708, jitter: 0.0, family: Family::Citation { avg_deg: 4.0 }, instances: 1, scale_down: 1 },
        // CITESEER: 3264 / 4536
        Recipe { name: "CITESEER", n: 3264, jitter: 0.0, family: Family::Citation { avg_deg: 2.8 }, instances: 1, scale_down: 1 },
    ]
}

/// OGB-like big citation graphs for the §6.2 ego-network workload,
/// scaled down (ARXIV 169k → 16k, MAG 1.9M → 24k).
pub fn ogb_like() -> Vec<Recipe> {
    // avg_deg matched to the OGB graphs' undirected degree (ARXIV ≈ 13.7)
    // so 1-hop ego networks hit the Table 2 ego sizes (~33 / ~31 nodes
    // when centers are drawn edge-endpoint-biased, as hubs dominate cost).
    vec![
        Recipe { name: "OGB-ARXIV", n: 16_000, jitter: 0.0, family: Family::Citation { avg_deg: 13.7 }, instances: 1, scale_down: 10 },
        Recipe { name: "OGB-MAG", n: 24_000, jitter: 0.0, family: Family::Citation { avg_deg: 11.0 }, instances: 1, scale_down: 80 },
    ]
}

/// The 11 large SNAP networks of Table 1, scaled down ~20× (factor in
/// `scale_down`); family chosen to match each network's structure class
/// and therefore its reduction profile.
pub fn large_networks() -> Vec<Recipe> {
    vec![
        // com-youtube 1,134,890 / 2,987,624 — social, big leaf fringe
        Recipe { name: "com-youtube", n: 56_744, jitter: 0.0, family: Family::Social { m: 5, leaf_frac: 0.59 }, instances: 1, scale_down: 20 },
        // com-amazon 334,863 / 925,872 — co-purchase, twin products
        Recipe { name: "com-amazon", n: 16_743, jitter: 0.0, family: Family::HubFringe { m: 2, leaf_frac: 0.22, twin_frac: 0.15 }, instances: 1, scale_down: 20 },
        // com-dblp 317,080 / 1,049,866 — collaboration cliques
        Recipe { name: "com-dblp", n: 15_854, jitter: 0.0, family: Family::CliqueCover { k: 5, overlap: 0.08 }, instances: 1, scale_down: 20 },
        // web-Stanford 281,903 / 1,992,636 — web graph, template twins
        Recipe { name: "web-Stanford", n: 14_095, jitter: 0.0, family: Family::HubFringe { m: 5, leaf_frac: 0.10, twin_frac: 0.55 }, instances: 1, scale_down: 20 },
        // emailEuAll 265,214 / 364,481 — star-dominated email (95% reduction!)
        Recipe { name: "emailEuAll", n: 13_260, jitter: 0.0, family: Family::Social { m: 1, leaf_frac: 0.75 }, instances: 1, scale_down: 20 },
        // soc-Epinions1 75,879 / 405,740 — trust net: dense core, 1-review fringe
        Recipe { name: "soc-Epinions1", n: 7_588, jitter: 0.0, family: Family::Social { m: 11, leaf_frac: 0.57 }, instances: 1, scale_down: 10 },
        // p2pGnutella31 62,586 / 147,892 — p2p overlay, leaf peers
        Recipe { name: "p2pGnutella31", n: 6_258, jitter: 0.0, family: Family::Social { m: 3, leaf_frac: 0.46 }, instances: 1, scale_down: 10 },
        // Brightkite 58,228 / 214,078 — location social
        Recipe { name: "Brightkite_edges", n: 5_822, jitter: 0.0, family: Family::HubFringe { m: 5, leaf_frac: 0.44, twin_frac: 0.04 }, instances: 1, scale_down: 10 },
        // Email-Enron 36,692 / 183,831 — email, hub-heavy with assistants(twins)
        Recipe { name: "Email-Enron", n: 3_669, jitter: 0.0, family: Family::HubFringe { m: 7, leaf_frac: 0.65, twin_frac: 0.05 }, instances: 1, scale_down: 10 },
        // CA-CondMat 23,133 / 93,439 — collaboration cliques
        Recipe { name: "CA-CondMat", n: 4_626, jitter: 0.0, family: Family::CliqueCover { k: 5, overlap: 0.10 }, instances: 1, scale_down: 5 },
        // oregon1_010526 11,174 / 23,409 — AS topology, stub ASes + twins
        Recipe { name: "oregon1_010526", n: 2_234, jitter: 0.0, family: Family::HubFringe { m: 2, leaf_frac: 0.50, twin_frac: 0.10 }, instances: 1, scale_down: 5 },
    ]
}

/// Look up any recipe by (case-insensitive) name across all registries.
pub fn find(name: &str) -> Result<Recipe> {
    let lname = name.to_ascii_lowercase();
    kernel_datasets()
        .into_iter()
        .chain(node_datasets())
        .chain(ogb_like())
        .chain(large_networks())
        .find(|r| r.name.to_ascii_lowercase() == lname)
        .ok_or_else(|| Error::UnknownDataset(name.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_paper_tables() {
        assert_eq!(kernel_datasets().len(), 11);
        assert_eq!(large_networks().len(), 11);
        assert_eq!(node_datasets().len(), 2);
        assert_eq!(ogb_like().len(), 2);
    }

    #[test]
    fn find_is_case_insensitive() {
        assert!(find("twitter").is_ok());
        assert!(find("COM-YOUTUBE").is_ok());
        assert!(find("nope").is_err());
    }

    #[test]
    fn names_are_unique() {
        let mut names: Vec<&str> = kernel_datasets()
            .iter()
            .chain(node_datasets().iter())
            .chain(ogb_like().iter())
            .chain(large_networks().iter())
            .map(|r| r.name)
            .collect();
        let before = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), before);
    }

    #[test]
    fn kernel_sizes_near_table2() {
        // spot-check edge densities against Table 2 (±40%)
        for (name, want_m) in [("DHFR", 44.5), ("ENZYMES", 62.1), ("SYNNEW", 196.3)] {
            let r = find(name).unwrap();
            let gs = (0..6).map(|i| r.make(123, i)).collect::<Vec<_>>();
            let avg_m = gs.iter().map(|g| g.m()).sum::<usize>() as f64 / gs.len() as f64;
            assert!(
                (avg_m - want_m).abs() / want_m < 0.45,
                "{name}: avg m {avg_m:.1} vs table {want_m}"
            );
        }
    }

    #[test]
    fn large_networks_scale_factor_consistent() {
        for r in large_networks() {
            assert!(r.scale_down >= 5, "{} must record its scale", r.name);
            let g = r.make(1, 0);
            assert_eq!(g.n(), r.n, "{}", r.name);
        }
    }
}
