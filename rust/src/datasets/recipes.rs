//! Generator recipes: a dataset = a structural family + size parameters +
//! an instance count, all seeded.

use crate::graph::{gen, Graph, GraphBuilder};
use crate::util::Rng;

/// Structural family of a synthetic dataset.
#[derive(Clone, Debug, PartialEq)]
pub enum Family {
    /// Erdős–Rényi with edge probability `p`.
    Er { p: f64 },
    /// Barabási–Albert with `m` edges per vertex.
    Ba { m: usize },
    /// Holme–Kim powerlaw-cluster (BA + triad closure `pt`).
    Plc { m: usize, pt: f64 },
    /// Random geometric graph with radius `r` (point-cloud-like; FIRSTMM).
    Rgg { r: f64 },
    /// Small-world ring.
    Ws { k: usize, beta: f64 },
    /// Molecule-like: random tree plus `extra` ring-closing edges
    /// (NCI1 / DHFR class).
    Molecule { extra: usize },
    /// Citation-like: preferential tree grown to `target_m` edges
    /// (CORA / CITESEER / ARXIV class).
    Citation { avg_deg: f64 },
    /// Social: BA core plus a dominated leaf fringe (`leaf_frac` of n)
    /// (com-youtube / email class — drives high PrunIT reduction).
    Social { m: usize, leaf_frac: f64 },
    /// Collaboration: union of overlapping cliques of mean size `k`
    /// (CA-CondMat / com-dblp class — twin-heavy, high reduction).
    /// `overlap` ∈ [0,1]: fraction of members drawn globally (higher →
    /// more multi-clique vertices → fewer dominated).
    CliqueCover { k: usize, overlap: f64 },
    /// Hub-and-fringe: BA core + `leaf_frac` pendant vertices +
    /// `twin_frac` duplicated vertices (same neighbourhood as a random
    /// core vertex — dominated twins whose removal cuts many edges).
    /// Models email / web / trust networks (Table 1 reduction profiles).
    HubFringe { m: usize, leaf_frac: f64, twin_frac: f64 },
    /// Dense ego network (TWITTER/FACEBOOK): powerlaw-cluster core with a
    /// `periphery` fraction of low-degree members (degree 1..=5) — the
    /// ≈20% that CoralTDA peels even at k=5 (paper Fig 4).
    Ego { m: usize, pt: f64, periphery: f64 },
    /// Triangulated surface mesh (FIRSTMM's 3d-point-cloud graphs):
    /// grid + `diag_frac` of the unit squares triangulated. Meshes carry
    /// almost no dominated vertices (neighbourhoods never nest away from
    /// the boundary) — the paper's "strong cores" explanation for
    /// FIRSTMM's <10% PrunIT reduction.
    Mesh { diag_frac: f64 },
}

/// A dataset recipe: named, sized, seeded.
#[derive(Clone, Debug)]
pub struct Recipe {
    /// Paper dataset this stands in for.
    pub name: &'static str,
    /// Target (mean) graph order.
    pub n: usize,
    /// Relative jitter on n across instances (kernel datasets vary).
    pub jitter: f64,
    pub family: Family,
    /// Number of graph instances (1 for node-classification / large nets).
    pub instances: usize,
    /// Scale-down factor vs the paper's dataset (1 = full scale).
    pub scale_down: usize,
}

impl Recipe {
    /// Generate instance `idx` deterministically from `seed`.
    pub fn make(&self, seed: u64, idx: usize) -> Graph {
        let mut rng = Rng::new(seed ^ (0x9E37 + idx as u64).wrapping_mul(0xA24B_AED4_963E_E407));
        let n = if self.jitter > 0.0 {
            let lo = ((self.n as f64) * (1.0 - self.jitter)).max(3.0) as usize;
            let hi = ((self.n as f64) * (1.0 + self.jitter)) as usize;
            rng.range(lo, hi.max(lo + 1))
        } else {
            self.n
        };
        let s = rng.next_u64();
        match self.family {
            Family::Er { p } => gen::erdos_renyi(n, p, s),
            Family::Ba { m } => gen::barabasi_albert(n, m, s),
            Family::Plc { m, pt } => gen::powerlaw_cluster(n, m, pt, s),
            Family::Rgg { r } => gen::random_geometric(n, r, s),
            Family::Ws { k, beta } => gen::watts_strogatz(n.max(k + 2), k, beta, s),
            Family::Molecule { extra } => molecule(n, extra, s),
            Family::Citation { avg_deg } => citation(n, (n as f64 * avg_deg / 2.0) as usize, s),
            Family::Social { m, leaf_frac } => social(n, m, leaf_frac, s),
            Family::CliqueCover { k, overlap } => clique_cover(n, k, overlap, s),
            Family::HubFringe { m, leaf_frac, twin_frac } => {
                hub_fringe(n, m, leaf_frac, twin_frac, s)
            }
            Family::Ego { m, pt, periphery } => ego(n, m, pt, periphery, s),
            Family::Mesh { diag_frac } => mesh(n, diag_frac, s),
        }
    }

    /// All instances of this dataset.
    pub fn make_all(&self, seed: u64) -> Vec<Graph> {
        (0..self.instances).map(|i| self.make(seed, i)).collect()
    }
}

/// Random tree (uniform random parent) plus `extra` ring-closing edges.
pub fn molecule(n: usize, extra: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut b = GraphBuilder::new(n);
    for v in 1..n {
        let parent = rng.below(v) as u32;
        b.add_edge(v as u32, parent);
    }
    let mut added = 0usize;
    let mut attempts = 0usize;
    while added < extra && attempts < extra * 20 + 20 {
        attempts += 1;
        if n < 2 {
            break;
        }
        let a = rng.below(n) as u32;
        let c = rng.below(n) as u32;
        if a != c {
            b.add_edge(a, c);
            added += 1;
        }
    }
    b.build()
}

/// Preferential-attachment tree densified to `target_m` edges with
/// degree-biased extra links — citation-network degree profile.
pub fn citation(n: usize, target_m: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut chips: Vec<u32> = vec![0];
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for v in 1..n as u32 {
        let t = chips[rng.below(chips.len())];
        edges.push((v, t));
        chips.push(v);
        chips.push(t);
    }
    let mut guard = 0usize;
    while edges.len() < target_m && guard < 20 * target_m + 100 {
        guard += 1;
        let a = chips[rng.below(chips.len())];
        let b = chips[rng.below(chips.len())];
        if a != b {
            edges.push((a, b));
            chips.push(a);
            chips.push(b);
        }
    }
    Graph::from_edges(n, &edges)
}

/// BA(core, m) plus `leaf_frac·n` pendant vertices attached
/// degree-biased — the dominated fringe of social/email networks.
pub fn social(n: usize, m: usize, leaf_frac: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let leaves = ((n as f64) * leaf_frac) as usize;
    let core_n = n.saturating_sub(leaves).max(m + 2);
    let core = gen::barabasi_albert(core_n, m, rng.next_u64());
    let mut b = GraphBuilder::new(n);
    for (u, v) in core.edges() {
        b.add_edge(u, v);
    }
    // Degree-biased chips from the core.
    let mut chips: Vec<u32> = Vec::new();
    for v in 0..core_n as u32 {
        for _ in 0..core.degree(v) {
            chips.push(v);
        }
    }
    for leaf in core_n..n {
        let t = chips[rng.below(chips.len())];
        b.add_edge(leaf as u32, t);
    }
    b.ensure_vertices(n);
    b.build()
}

/// Union of overlapping random cliques of size ~k covering n vertices —
/// collaboration-network structure (papers = cliques of co-authors).
/// `overlap` = probability a member is drawn globally rather than from
/// the clique's contiguous anchor block.
pub fn clique_cover(n: usize, k: usize, overlap: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let k = k.max(2);
    let mut b = GraphBuilder::new(n);
    let cliques = (2 * n / k).max(1);
    for _ in 0..cliques {
        let size = rng.range(2, 2 * k - 1).min(n);
        // anchor-biased membership: local block = "research group",
        // global draws = outside collaborators.
        let anchor = rng.below(n);
        let mut members: Vec<u32> = Vec::with_capacity(size);
        for j in 0..size {
            let v = if rng.chance(overlap) {
                rng.below(n) as u32
            } else {
                ((anchor + j) % n) as u32
            };
            if !members.contains(&v) {
                members.push(v);
            }
        }
        for i in 0..members.len() {
            for j in (i + 1)..members.len() {
                b.add_edge(members[i], members[j]);
            }
        }
    }
    b.ensure_vertices(n);
    b.build()
}

/// BA core + pendant leaves + duplicated twins. Twins copy the full
/// neighbourhood of a random core vertex, so they are dominated and
/// their removal cuts `deg` edges each — the mechanism behind Table 1
/// rows where edge reduction rivals or exceeds vertex reduction
/// (web-Stanford, com-amazon, com-dblp).
pub fn hub_fringe(n: usize, m: usize, leaf_frac: f64, twin_frac: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let leaves = ((n as f64) * leaf_frac) as usize;
    let twins = ((n as f64) * twin_frac) as usize;
    let core_n = n.saturating_sub(leaves + twins).max(m + 2);
    let core = gen::barabasi_albert(core_n, m, rng.next_u64());
    let mut b = GraphBuilder::new(n);
    for (u, v) in core.edges() {
        b.add_edge(u, v);
    }
    let mut chips: Vec<u32> = Vec::new();
    for v in 0..core_n as u32 {
        for _ in 0..core.degree(v) {
            chips.push(v);
        }
    }
    let mut next = core_n;
    for _ in 0..twins.min(n.saturating_sub(core_n)) {
        // partial twin: copy a random subset of a degree-biased core
        // vertex's neighbourhood, plus the original itself. Any subset
        // keeps N[twin] ⊆ N[orig], so the twin stays dominated while
        // carrying tunable edge weight.
        let orig = chips[rng.below(chips.len())];
        let q = 0.4 + 0.4 * rng.f64();
        for &w in core.neighbors(orig) {
            if rng.chance(q) {
                b.add_edge(next as u32, w);
            }
        }
        b.add_edge(next as u32, orig); // twin adjacent to its original
        next += 1;
    }
    while next < n {
        let t = chips[rng.below(chips.len())];
        b.add_edge(next as u32, t);
        next += 1;
    }
    b.ensure_vertices(n);
    b.build()
}

/// Dense social ego network: powerlaw-cluster core + `periphery` fraction
/// of members with degree 1..=5 (friends-of-friends on the rim).
pub fn ego(n: usize, m: usize, pt: f64, periphery: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let rim = ((n as f64) * periphery) as usize;
    let core_n = n.saturating_sub(rim).max(m + 2);
    let core = gen::powerlaw_cluster(core_n, m, pt, rng.next_u64());
    let mut b = GraphBuilder::new(n);
    for (u, v) in core.edges() {
        b.add_edge(u, v);
    }
    for v in core_n..n {
        let deg = rng.range(1, 5);
        // attach to a random clique-ish set: a core vertex and some of its
        // neighbours, so rim members sit on real communities
        let anchor = rng.below(core_n) as u32;
        b.add_edge(v as u32, anchor);
        let nbrs = core.neighbors(anchor);
        for _ in 1..deg {
            if nbrs.is_empty() {
                break;
            }
            b.add_edge(v as u32, nbrs[rng.below(nbrs.len())]);
        }
    }
    b.ensure_vertices(n);
    b.build()
}

/// Triangulated grid mesh of ~n vertices: w×h lattice, each unit square
/// gaining a diagonal with probability `diag_frac`.
pub fn mesh(n: usize, diag_frac: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let w = (n as f64).sqrt().round().max(2.0) as usize;
    let h = (n + w - 1) / w;
    let id = |x: usize, y: usize| (y * w + x) as u32;
    let mut b = GraphBuilder::new(w * h);
    for y in 0..h {
        for x in 0..w {
            if x + 1 < w {
                b.add_edge(id(x, y), id(x + 1, y));
            }
            if y + 1 < h {
                b.add_edge(id(x, y), id(x, y + 1));
            }
            if x + 1 < w && y + 1 < h && rng.chance(diag_frac) {
                // random diagonal orientation
                if rng.chance(0.5) {
                    b.add_edge(id(x, y), id(x + 1, y + 1));
                } else {
                    b.add_edge(id(x + 1, y), id(x, y + 1));
                }
            }
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_has_few_dominated_vertices() {
        let g = mesh(900, 0.6, 7);
        let f = crate::complex::Filtration::degree_superlevel(&g);
        let r = crate::prune::prunit(&g, &f).unwrap();
        let red = 100.0 * r.removed as f64 / g.n() as f64;
        assert!(red < 15.0, "mesh PrunIT reduction should be small, got {red:.1}%");
    }

    #[test]
    fn molecule_is_connected_ringy() {
        let g = molecule(40, 4, 1);
        assert_eq!(g.n(), 40);
        assert!(g.is_connected());
        assert!(g.m() >= 39, "tree + rings");
    }

    #[test]
    fn citation_hits_edge_target() {
        let g = citation(500, 1000, 2);
        assert!(g.is_connected());
        let m = g.m() as f64;
        assert!((m - 1000.0).abs() < 120.0, "m={m}");
    }

    #[test]
    fn social_has_leaf_fringe() {
        let g = social(300, 2, 0.4, 3);
        assert_eq!(g.n(), 300);
        let leaves = (0..g.n() as u32).filter(|&v| g.degree(v) == 1).count();
        assert!(leaves >= 90, "want a large pendant fringe, got {leaves}");
    }

    #[test]
    fn clique_cover_has_triangles() {
        let g = clique_cover(200, 6, 0.3, 4);
        assert!(crate::graph::clustering::average(&g) > 0.3);
    }

    #[test]
    fn hub_fringe_twins_are_dominated() {
        let g = hub_fringe(300, 3, 0.2, 0.3, 5);
        assert_eq!(g.n(), 300);
        let f = crate::complex::Filtration::degree_superlevel(&g);
        let dominated = (0..g.n() as u32)
            .filter(|&u| crate::prune::find_dominator(&g, &f, u).is_some())
            .count();
        // every twin and leaf should be dominated initially
        assert!(dominated >= 120, "dominated={dominated}");
    }

    #[test]
    fn ego_has_dense_core_sparse_rim() {
        let g = ego(200, 10, 0.8, 0.25, 6);
        assert_eq!(g.n(), 200);
        let core = crate::kcore::coreness(&g);
        let low = core.iter().filter(|&&c| c <= 5).count();
        assert!(low >= 30, "rim should be low-core, got {low}");
        assert!(*core.iter().max().unwrap() >= 8, "core should be dense");
    }

    #[test]
    fn recipe_instances_deterministic_and_distinct() {
        let r = Recipe {
            name: "TEST",
            n: 50,
            jitter: 0.2,
            family: Family::Ba { m: 2 },
            instances: 3,
            scale_down: 1,
        };
        let a = r.make_all(7);
        let b = r.make_all(7);
        assert_eq!(a.len(), 3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x, y);
        }
        assert_ne!(a[0], a[1], "instances should differ");
    }

    #[test]
    fn jitter_zero_is_exact_n() {
        let r = Recipe {
            name: "T",
            n: 64,
            jitter: 0.0,
            family: Family::Er { p: 0.1 },
            instances: 1,
            scale_down: 1,
        };
        assert_eq!(r.make(1, 0).n(), 64);
    }
}
