//! Dataset registry (S11): seeded synthetic stand-ins for the paper's
//! datasets (Table 2 graph/node-classification sets and the 11 large SNAP
//! networks of Table 1). No network access exists in this environment, so
//! each dataset is a generator recipe whose order/size/structure class is
//! matched to the published statistics; large networks are scaled down
//! (factor recorded per recipe) so that full-PH baselines finish.
//! See DESIGN.md §4 for the substitution argument.

pub mod recipes;
pub mod registry;

pub use recipes::{Family, Recipe};
pub use registry::{find, kernel_datasets, large_networks, node_datasets, ogb_like};
